"""Paper Fig. 3 solver: nonlinear 3-D two-phase flow (porosity waves).

The implicit (multigrid-preconditioned CG) pressure solve advances the
same physics at 10x the explicit stability-limit ``dt``, so the default
``mgcg`` run takes 10x fewer steps to the same horizon.

Run:  PYTHONPATH=src python examples/twophase.py [--nx 48] [--method mgcg]
      REPRO_DEVICES=8 PYTHONPATH=src python examples/twophase.py
      PYTHONPATH=src python examples/twophase.py --method explicit --nt 150
"""

import argparse
import os

if os.environ.get("REPRO_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={os.environ['REPRO_DEVICES']}"
    )

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nx", type=int, default=40)
    ap.add_argument("--nt", type=int, default=None,
                    help="steps (default: 150 explicit, 15 implicit — the "
                         "same simulated horizon)")
    ap.add_argument("--method", default="mgcg",
                    choices=["explicit", "cg", "mgcg"])
    ap.add_argument("--overlap", action="store_true",
                    help="hide_apply overlap on the implicit operator")
    ap.add_argument("--periodic", action="store_true",
                    help="periodic x/y dims (works with every method: the "
                         "implicit pressure operator stays nonsingular)")
    ap.add_argument("--heartbeat", type=int, default=0, metavar="K",
                    help="rank-0 solver heartbeat event every K iterations "
                         "(installs the solve-health watchdogs)")
    ap.add_argument("--flight-record", metavar="DIR", default=None,
                    help="per-rank flight recorder dumping to DIR on "
                         "failure (diagnose with python -m "
                         "repro.telemetry.diag DIR)")
    args = ap.parse_args()

    import jax

    from repro import fields
    from repro.apps.twophase import TwoPhase3D

    print(f"devices: {jax.device_count()}")
    per = (True, True, False) if args.periodic else (False, False, False)
    obs = dict(heartbeat=args.heartbeat, flight_dir=args.flight_record)
    if args.method == "explicit":
        app = TwoPhase3D(nx=args.nx, ny=args.nx, nz=args.nx, hide=(8, 2, 2),
                         periodic=per, **obs)
    else:
        # dt defaults to 10x the explicit stability limit — the point of
        # the implicit pressure projection
        app = TwoPhase3D(nx=args.nx, ny=args.nx, nz=args.nx,
                         method=args.method, overlap=args.overlap, tol=1e-6,
                         periodic=per, **obs)
    nt = args.nt if args.nt is not None else \
        (150 if args.method == "explicit" else 15)
    g = app.grid
    print(f"global grid {g.global_shape} over dims {g.dims}; "
          f"method={args.method} dt={app.dt:.3e} "
          f"({app.dt / app.dt_limit:.0f}x the explicit limit), {nt} steps")
    S = app.init_fields()
    phi0 = fields.gather(S.phi)
    S, infos = app.run(nt, S)
    P = fields.gather(S.Pe)
    F = fields.gather(S.phi)
    if infos:
        iters = [i.iterations for i in infos]
        print(f"implicit pressure solves: {sum(iters)} CG iterations total "
              f"({min(iters)}-{max(iters)}/step), all converged: "
              f"{all(i.converged for i in infos)}")
    # the porosity wave migrates upward: the center of mass of the anomaly rises
    z = np.arange(F.shape[2])
    anom0 = phi0 - phi0.min()
    anom1 = F - F.min()
    z0 = (anom0.sum((0, 1)) * z).sum() / anom0.sum()
    z1 = (anom1.sum((0, 1)) * z).sum() / anom1.sum()
    print(f"porosity anomaly z-center: {z0:.2f} -> {z1:.2f} "
          f"(wave {'rose' if z1 > z0 else 'did not rise'})")
    print(f"|Pe|_max = {np.abs(P).max():.4f}, phi in [{F.min():.4f}, {F.max():.4f}]")
    g.finalize()
    print("OK")


if __name__ == "__main__":
    main()
