"""Context parallelism demo: 500k-token-style prefill via sequence sharding.

The paper's halo-exchange pattern on the token grid: sliding-window
attention takes a kv halo from the left neighbor, full attention runs
ring attention, Mamba layers pass conv halos + chunk states. Verifies the
sharded forward equals the plain forward on a reduced config.

Run:  REPRO_DEVICES=8 PYTHONPATH=src python examples/context_parallel.py
"""

import os

if os.environ.get("REPRO_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={os.environ['REPRO_DEVICES']}"
    )

import dataclasses
import importlib

import numpy as np


def main():
    import jax
    import jax.numpy as jnp

    from repro.distributed.context_parallel import context_parallel_logits
    from repro.models import params as pm, transformer as tf

    n = jax.device_count()
    print(f"devices: {n}")
    for mod in ["gemma3_4b", "mamba2_1p3b", "jamba_v01_52b"]:
        cfg = importlib.import_module(f"repro.configs.{mod}").SMOKE
        cfg = dataclasses.replace(cfg, dtype="float32")
        params = pm.materialize(tf.param_specs(cfg), jax.random.PRNGKey(0),
                                jnp.float32)
        rng = np.random.RandomState(0)
        T = 16 * n
        toks = jnp.asarray(rng.randint(0, cfg.vocab, (2, T)), jnp.int32)
        h, _, _ = tf.fwd(params, cfg, toks, mode="train", remat="none")
        ref = np.asarray(tf.logits_fn(params, cfg, h))
        mesh = jax.make_mesh((n,), ("sp",))
        got = np.asarray(context_parallel_logits(params, cfg, toks, mesh, axis="sp"))
        err = np.abs(got - ref).max() / (np.abs(ref).max() + 1e-9)
        print(f"  {cfg.name:16s} T={T} over {n} shards: rel err {err:.2e}")
        assert err < 1e-3
    print("OK")


if __name__ == "__main__":
    main()
