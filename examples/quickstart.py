"""Paper Fig. 1, transliterated: 3-D heat diffusion with 3 grid calls.

Run:  PYTHONPATH=src python examples/quickstart.py [--nx 64] [--nt 100]
      REPRO_DEVICES=8 PYTHONPATH=src python examples/quickstart.py   # multi-device

The solver is single-device code on the LOCAL grid; `init_global_grid`,
`update_halo`/`hide_communication` and `finalize` make it distributed —
the paper's 3-function recipe.
"""

import argparse
import os

if os.environ.get("REPRO_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={os.environ['REPRO_DEVICES']}"
    )

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nx", type=int, default=48)
    ap.add_argument("--nt", type=int, default=100)
    ap.add_argument("--kernel", default="ref", choices=["ref", "interpret", "pallas"])
    ap.add_argument("--no-hide", action="store_true")
    args = ap.parse_args()

    import jax

    from repro.apps.heat3d import Heat3D

    print(f"devices: {jax.device_count()}")
    app = Heat3D(
        nx=args.nx, ny=args.nx, nz=args.nx,
        hide=None if args.no_hide else (16, 2, 2),
        use_kernel=args.kernel,
    )
    g = app.grid
    print(f"implicit global grid: {g.global_shape} over dims {g.dims} "
          f"(local {g.local_shape}, overlap {g.overlap})")

    T, Ci = app.init_fields()
    T, _ = app.run(args.nt, T, Ci)
    G = g.gather(T)
    print(f"after {args.nt} steps: T[center] = {G[tuple(s // 2 for s in G.shape)]:.6f}, "
          f"mean = {G.mean():.6f}")

    if args.nx <= 48:
        ref = app.oracle(args.nt)
        err = np.abs(G - ref).max()
        print(f"max |distributed - single-array oracle| = {err:.3e}")
        assert err < 1e-4
    g.finalize()
    print("OK")


if __name__ == "__main__":
    main()
