"""Quickstart: 3-D full-stress variable-viscosity Stokes on the
staggered grid.

Velocities live on cell faces, pressure and viscosity in cell centers
(``repro.fields``); the momentum operator is the full symmetric-gradient
stress ``-div(2 eta D(V))`` (components coupled through the edge shear
terms).  The velocity block is solved by CG over the whole staggered
FieldSet, preconditioned by the COUPLED staggered multigrid cycle (each
component transferred on its own face grid); the pressure by CG on the
viscosity-preconditioned Schur complement — one velocity solve per outer
matvec, several-fold fewer outer solves than the classic Uzawa loop.

Run on 8 fake CPU devices:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/stokes.py

``--heartbeat K`` streams a rank-0 health heartbeat every K solver
iterations; ``--flight-record DIR`` arms the per-rank flight recorder
(post-mortem via ``python -m repro.telemetry.diag DIR``).
"""

import argparse

import jax

jax.config.update("jax_platform_name", "cpu")
jax.config.update("jax_enable_x64", True)

from repro.apps.stokes import Stokes3D          # noqa: E402
from repro import fields                        # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--heartbeat", type=int, default=0, metavar="K",
                    help="rank-0 solver heartbeat event every K iterations "
                         "(installs the solve-health watchdogs)")
    ap.add_argument("--flight-record", metavar="DIR", default=None,
                    help="per-rank flight recorder dumping to DIR on "
                         "failure (diagnose with python -m "
                         "repro.telemetry.diag DIR)")
    args = ap.parse_args()

    # Local block 10^3 (incl. halo) per device; the implicit global grid
    # is assembled from the device count (e.g. 8 devices -> 2x2x2 blocks).
    app = Stokes3D(nx=10, ny=10, nz=10, eta_amp=0.5,
                   heartbeat=args.heartbeat, flight_dir=args.flight_record)
    print(f"global grid {app.grid.global_shape}, "
          f"{app.grid.dims} device blocks")

    # The flagship workload: the staggered velocity system as ONE Krylov
    # vector -- plain CG vs the coupled staggered-MG preconditioner vs
    # the historical center-cycle baseline.
    _, plain = app.velocity_solve(precond=None, tol=1e-8)
    _, stag = app.velocity_solve(precond="stress", tol=1e-8)
    _, cent = app.velocity_solve(precond="center", tol=1e-8)
    print(f"velocity solve: plain CG {plain.iterations} iters, "
          f"staggered-MG CG {stag.iterations} iters, "
          f"center-cycle CG {cent.iterations} iters")

    # Full Stokes: CG on the viscosity-preconditioned Schur complement
    # (each outer iteration = one velocity solve); try method="uzawa"
    # to compare with the classic Richardson loop.
    V, P, info = app.solve(tol=1e-6, method="schur")
    print(f"stokes (schur-cg): {info.outer_iterations} outer / "
          f"{info.inner_iterations} inner iters, "
          f"div residual {info.relres_div:.1e}, "
          f"momentum residual {info.relres_momentum:.1e}")

    # Staggered fields gather to their VALID deduplicated global shape
    # (faces: N-1 points along the staggered dim).
    vx = fields.gather(V.vx)
    print(f"vx valid global shape {vx.shape}, max |vx| = {abs(vx).max():.3e}")


if __name__ == "__main__":
    main()
