"""Quickstart: 3-D variable-viscosity Stokes on the staggered grid.

Velocities live on cell faces, pressure and viscosity in cell centers
(``repro.fields``); the velocity block is solved by CG over the whole
staggered FieldSet with a multigrid V-cycle preconditioner, the pressure
by viscosity-scaled Uzawa steps.

Run on 8 fake CPU devices:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/stokes.py
"""

import jax

jax.config.update("jax_platform_name", "cpu")
jax.config.update("jax_enable_x64", True)

from repro.apps.stokes import Stokes3D          # noqa: E402
from repro import fields                        # noqa: E402


def main():
    # Local block 10^3 (incl. halo) per device; the implicit global grid
    # is assembled from the device count (e.g. 8 devices -> 2x2x2 blocks).
    app = Stokes3D(nx=10, ny=10, nz=10, eta_amp=0.5)
    print(f"global grid {app.grid.global_shape}, "
          f"{app.grid.dims} device blocks")

    # The flagship workload: the staggered velocity system as ONE Krylov
    # vector -- plain CG vs multigrid-preconditioned CG.
    _, plain = app.velocity_solve(precond=False, tol=1e-8)
    _, mgcg = app.velocity_solve(precond=True, tol=1e-8)
    print(f"velocity solve: plain CG {plain.iterations} iters, "
          f"MG-preconditioned CG {mgcg.iterations} iters")

    # Full Stokes: Uzawa outer loop around warm-started velocity solves.
    V, P, info = app.solve(tol=1e-6)
    print(f"stokes: {info.outer_iterations} outer / "
          f"{info.inner_iterations} inner iters, "
          f"div residual {info.relres_div:.1e}, "
          f"momentum residual {info.relres_momentum:.1e}")

    # Staggered fields gather to their VALID deduplicated global shape
    # (faces: N-1 points along the staggered dim).
    vx = fields.gather(V.vx)
    print(f"vx valid global shape {vx.shape}, max |vx| = {abs(vx).max():.3e}")


if __name__ == "__main__":
    main()
